"""Pallas TPU kernel: fused tiled butterfly counting.

Computes  B = sum_{u<v} C(W_uv, 2),  W = A @ A.T  without ever materializing
the [n_i, n_i] wedge matrix W.  The schedule is the blocked-Gram triangle:

  grid = (T, nk) where T enumerates row-tile pairs (u <= v) via scalar-
  prefetched index maps (the TPU-idiomatic way to walk a triangular grid),
  and nk walks the contraction (j) dimension.

Per (u, v) tile pair a VMEM fp32 scratch accumulates A_u @ A_v^T across the
nk steps (MXU matmuls, 128-aligned BlockSpecs); on the last step the fused
epilogue applies w(w-1)/2, masks the u==v diagonal tile to its strict upper
triangle, reduces the tile to one partial sum and stores it.  Padding rows /
columns are all-zero and therefore contribute C(0,2) = 0 — no masking needed
beyond the triangle.

VMEM footprint per step: 2 * bi*bk (operand tiles) + bi*bi (scratch), fp32.
Default (bi=256, bk=512): 1 MiB + 256 KiB — comfortably inside a v5e core's
~16 MiB VMEM with double buffering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["butterfly_pairs_kernel_call", "butterfly_pairs_windows_kernel_call",
           "butterfly_pairs_windows_kernel_multiset_call"]


def _triangle_pairs(nu: int):
    """Triangular tile-pair enumeration (u <= v) as scalar-prefetch arrays."""
    upair, vpair = [], []
    for u in range(nu):
        for v in range(u, nu):
            upair.append(u)
            vpair.append(v)
    return (jnp.asarray(upair, dtype=jnp.int32),
            jnp.asarray(vpair, dtype=jnp.int32))


def _kernel(upair_ref, vpair_ref, au_ref, av_ref, out_ref, acc_ref, *, nk: int, bi: int):
    t = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    au = au_ref[...].astype(jnp.float32)
    av = av_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        au, av, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        u = upair_ref[t]
        v = vpair_ref[t]
        w = acc_ref[...]
        pairs = w * (w - 1.0) * 0.5
        row = jax.lax.broadcasted_iota(jnp.int32, (bi, bi), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (bi, bi), 1)
        # strict upper triangle in *global* i-indices:
        #   u < v  -> whole tile;  u == v -> row < col
        keep = (u * bi + row) < (v * bi + col)
        out_ref[0, 0] = jnp.sum(jnp.where(keep, pairs, 0.0))


def butterfly_pairs_kernel_call(
    adj: jax.Array,
    *,
    block_i: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Run the kernel over a padded biadjacency.  Returns per-tile-pair
    partial sums [T] (host reduces, optionally in float64).

    ``adj`` must already be padded to multiples of (block_i, block_k).
    """
    n_i, n_j = adj.shape
    if n_i % block_i or n_j % block_k:
        raise ValueError(f"adj {adj.shape} not padded to ({block_i},{block_k})")
    nu = n_i // block_i
    nk = n_j // block_k
    upair, vpair = _triangle_pairs(nu)
    T = int(upair.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, nk),
        in_specs=[
            pl.BlockSpec((block_i, block_k), lambda t, k, up, vp: (up[t], k)),
            pl.BlockSpec((block_i, block_k), lambda t, k, up, vp: (vp[t], k)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t, k, up, vp: (t, 0)),
        scratch_shapes=[pltpu.VMEM((block_i, block_i), jnp.float32)],
    )
    import functools

    fn = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bi=block_i),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, 1), jnp.float32),
        interpret=interpret,
    )
    return fn(upair, vpair, adj, adj)[:, 0]


def _windows_kernel(upair_ref, vpair_ref, au_ref, av_ref, out_ref, acc_ref,
                    *, nk: int, bi: int):
    """Window-batched twin of :func:`_kernel`: grid (B, T, nk) — the window
    axis is the *outermost* grid dimension, so one launch covers a whole
    bucket of same-capacity windows.  The accumulator scratch is still per
    (window, tile-pair): nk is the innermost dimension, so the k==0 zeroing
    and k==nk-1 epilogue bracket exactly one (b, t) accumulation run."""
    t = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    au = au_ref[0].astype(jnp.float32)
    av = av_ref[0].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        au, av, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        u = upair_ref[t]
        v = vpair_ref[t]
        w = acc_ref[...]
        pairs = w * (w - 1.0) * 0.5
        row = jax.lax.broadcasted_iota(jnp.int32, (bi, bi), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (bi, bi), 1)
        keep = (u * bi + row) < (v * bi + col)
        out_ref[0, 0] = jnp.sum(jnp.where(keep, pairs, 0.0))


def butterfly_pairs_windows_kernel_call(
    adjs: jax.Array,
    *,
    block_i: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Run the window-batched kernel over a [B, n_i, n_j] stack of padded
    biadjacencies.  Returns per-window per-tile-pair partials [B, T] — one
    kernel launch for the whole stack (window dimension in the grid), not
    one launch per window.

    Each ``adjs[b]`` must already be padded to multiples of
    ``(block_i, block_k)``; all-zero (padding) windows contribute 0.
    """
    B, n_i, n_j = adjs.shape
    if n_i % block_i or n_j % block_k:
        raise ValueError(
            f"adjs {adjs.shape} not padded to ({block_i},{block_k})")
    nu = n_i // block_i
    nk = n_j // block_k
    upair, vpair = _triangle_pairs(nu)
    T = int(upair.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, T, nk),
        in_specs=[
            pl.BlockSpec((1, block_i, block_k),
                         lambda b, t, k, up, vp: (b, up[t], k)),
            pl.BlockSpec((1, block_i, block_k),
                         lambda b, t, k, up, vp: (b, vp[t], k)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, t, k, up, vp: (b, t)),
        scratch_shapes=[pltpu.VMEM((block_i, block_i), jnp.float32)],
    )
    import functools

    fn = pl.pallas_call(
        functools.partial(_windows_kernel, nk=nk, bi=block_i),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T), jnp.float32),
        interpret=interpret,
    )
    return fn(upair, vpair, adjs, adjs)


def _windows_kernel_multiset(upair_ref, vpair_ref, au_ref, av_ref, out_ref,
                             accw_ref, accs_ref, *, nk: int, bi: int):
    """Multiset twin of :func:`_windows_kernel`.

    The biadjacency carries net multiplicities (A[u, j] = mult of edge
    (u, j); 0 if absent) and the per-tile epilogue applies the multiset Gram
    identity  B = sum_{u<v} (W_uv^2 - S_uv) / 2  with  W = A A^T  and
    S = (A*A)(A*A)^T — so two VMEM accumulators ride the nk contraction:
    acc_w for the weighted wedge Gram, acc_s for its squared-entry twin.
    With all multiplicities in {0, 1} this reduces exactly to the distinct
    kernel's w(w-1)/2 (then S == W), and padding stays all-zero => 0.
    """
    t = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        accw_ref[...] = jnp.zeros_like(accw_ref)
        accs_ref[...] = jnp.zeros_like(accs_ref)

    au = au_ref[0].astype(jnp.float32)
    av = av_ref[0].astype(jnp.float32)
    accw_ref[...] += jax.lax.dot_general(
        au, av, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    accs_ref[...] += jax.lax.dot_general(
        au * au, av * av, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        u = upair_ref[t]
        v = vpair_ref[t]
        w = accw_ref[...]
        s = accs_ref[...]
        pairs = (w * w - s) * 0.5
        row = jax.lax.broadcasted_iota(jnp.int32, (bi, bi), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (bi, bi), 1)
        keep = (u * bi + row) < (v * bi + col)
        out_ref[0, 0] = jnp.sum(jnp.where(keep, pairs, 0.0))


def butterfly_pairs_windows_kernel_multiset_call(
    adjs: jax.Array,
    *,
    block_i: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Window-batched multiset kernel over a [B, n_i, n_j] stack of padded
    *weighted* biadjacencies (entries = net edge multiplicities).  Same grid
    schedule as :func:`butterfly_pairs_windows_kernel_call`; the only extra
    cost is the second Gram accumulator (one more bi*bi fp32 VMEM scratch
    and one more MXU matmul per step)."""
    B, n_i, n_j = adjs.shape
    if n_i % block_i or n_j % block_k:
        raise ValueError(
            f"adjs {adjs.shape} not padded to ({block_i},{block_k})")
    nu = n_i // block_i
    nk = n_j // block_k
    upair, vpair = _triangle_pairs(nu)
    T = int(upair.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, T, nk),
        in_specs=[
            pl.BlockSpec((1, block_i, block_k),
                         lambda b, t, k, up, vp: (b, up[t], k)),
            pl.BlockSpec((1, block_i, block_k),
                         lambda b, t, k, up, vp: (b, vp[t], k)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, t, k, up, vp: (b, t)),
        scratch_shapes=[pltpu.VMEM((block_i, block_i), jnp.float32),
                        pltpu.VMEM((block_i, block_i), jnp.float32)],
    )
    import functools

    fn = pl.pallas_call(
        functools.partial(_windows_kernel_multiset, nk=nk, bi=block_i),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T), jnp.float32),
        interpret=interpret,
    )
    return fn(upair, vpair, adjs, adjs)
