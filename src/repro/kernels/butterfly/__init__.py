from .ops import (
    butterfly_count_pallas,
    butterfly_count_pallas_batched,
    butterfly_count_pallas_windows,
    butterfly_count_pallas_windows_multiset,
    butterfly_count_tiles,
)
from .ref import butterfly_count_ref

__all__ = [
    "butterfly_count_pallas",
    "butterfly_count_pallas_batched",
    "butterfly_count_pallas_windows",
    "butterfly_count_pallas_windows_multiset",
    "butterfly_count_tiles",
    "butterfly_count_ref",
]
