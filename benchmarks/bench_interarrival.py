"""Paper Figs 7-8: inter-arrival distribution of butterfly edge pairs —
right-skew + heavy tail on real-like streams (the inter-window butterfly
motivation)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.analysis import interarrival_distribution

from .common import bench_streams

__all__ = ["run"]


def run() -> list[tuple]:
    rows = []
    for name, s in bench_streams().items():
        t0 = time.perf_counter()
        d = interarrival_distribution(s.tau, s.edge_i, s.edge_j, max_edges=1500)
        dt = (time.perf_counter() - t0) * 1e6
        if d.size == 0:
            rows.append((f"interarrival/{name}", dt, "no butterflies"))
            continue
        med, mean, p95 = np.median(d), d.mean(), np.quantile(d, 0.95)
        rows.append((f"interarrival/{name}", dt,
                     f"median={med:.3g} mean={mean:.3g} p95={p95:.3g} "
                     f"skew={'right' if mean > med else 'left'}"))
    return rows
