"""SSPerf hillclimb — graphsage-reddit/ogb_products (most collective-bound).

Compiles the gather-based baseline and the halo-exchange variant on the
production pod mesh and reports the roofline terms of each.  Run as a
module IN ITS OWN PROCESS (forces 512 host devices):

    PYTHONPATH=src python -m benchmarks.hillclimb_graphsage
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_arch                        # noqa: E402
from repro.distributed.sharding import Sharder            # noqa: E402
from repro.launch.hlo_cost import analyze_hlo             # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.models.gnn.graphsage import sage_loss_halo     # noqa: E402

PEAK, HBM_BW, ICI = 197e12, 819e9, 50e9


def terms(hlo):
    r = analyze_hlo(hlo)
    return {
        "t_compute_ms": r["flops"] / PEAK * 1e3,
        "t_memory_ms": r["bytes"] / HBM_BW * 1e3,
        "t_collective_ms": r["collectives"]["total"] / ICI * 1e3,
        "collective_bytes": r["collectives"]["total"],
    }


def main() -> None:
    mesh = make_production_mesh()
    n_dev = mesh.size
    shard = Sharder.for_mesh(mesh)
    arch = get_arch("graphsage-reddit")
    cfg = arch.full_config()
    import dataclasses
    cfg = dataclasses.replace(cfg, d_in=100)     # ogb_products d_feat
    out = {}

    # -- baseline: gather-based cell (the registry step) ----------------------
    cell = arch.cells(cfg)["ogb_products"]
    step = cell.make_step(shard)
    with mesh:
        c = jax.jit(step, in_shardings=cell.in_shardings(shard),
                    donate_argnums=cell.donate).lower(*cell.abstract_inputs()).compile()
    out["gather_baseline"] = terms(c.as_text())
    out["gather_baseline"]["memory"] = {
        "temp_gb": c.memory_analysis().temp_size_in_bytes / 1e9}

    # -- halo-exchange variant -------------------------------------------------
    N = 2_449_408                     # padded ogb_products nodes
    n_loc = N // n_dev
    H = max(64, n_loc // 2)           # halo budget: 50% boundary per peer-set
    H_per_peer = max(1, H // n_dev)
    e_loc = 61_865_984 // n_dev * 2   # edge slots per device (2x skew margin)
    F, C = 100, cfg.n_classes
    sd = jax.ShapeDtypeStruct
    batch = {
        "x": sd((N, F), jnp.float32),
        "halo_send_idx": sd((n_dev, n_dev, H_per_peer), jnp.int32),
        "edge_src_ext": sd((n_dev, e_loc), jnp.int32),
        "edge_dst_loc": sd((n_dev, e_loc), jnp.int32),
        "edge_mask": sd((n_dev, e_loc), jnp.bool_),
        "labels_2d": sd((n_dev, n_loc), jnp.int32),
        "label_mask_2d": sd((n_dev, n_loc), jnp.float32),
    }
    params_abs = jax.eval_shape(
        lambda: __import__("repro.models.gnn.graphsage", fromlist=["init_sage"])
        .init_sage(jax.random.PRNGKey(0), cfg))
    axes = tuple(mesh.axis_names)

    def loss_fn(params, b):
        return sage_loss_halo(params, b, cfg, mesh, axes)

    with mesh:
        c2 = jax.jit(loss_fn).lower(params_abs, batch).compile()
    out["halo_exchange"] = terms(c2.as_text())
    out["halo_exchange"]["memory"] = {
        "temp_gb": c2.memory_analysis().temp_size_in_bytes / 1e9}
    out["halo_budget"] = {"H_per_peer": H_per_peer, "edge_slots": e_loc}

    os.makedirs("experiments/hillclimb", exist_ok=True)
    with open("experiments/hillclimb/graphsage_ogb.json", "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
