"""Butterfly-kernel micro-bench: tiled-JAX vs dense-Gram vs (interpret-mode)
Pallas on window-sized biadjacencies; derived column = GMAC/s of the Gram
contraction (the kernel's roofline axis).  A second section benches the
window executor end-to-end per tier on a windowized stream (bucketed
capacities — the production dispatch path).

Runs standalone as the CI smoke check:

    PYTHONPATH=src python benchmarks/bench_kernel.py --quick
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.butterfly import count_butterflies_dense, count_butterflies_tiled
from repro.core.executor import WindowExecutor
from repro.streams import bipartite_pa_stream

__all__ = ["run"]


def run(*, quick: bool = False) -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    shapes = [(512, 1024, 0.02)] if quick else [
        (1024, 2048, 0.01), (2048, 4096, 0.005)]
    for n_i, n_j, dens in shapes:
        adj = jnp.asarray((rng.random((n_i, n_j)) < dens), jnp.float32)
        macs = n_i * n_i * n_j / 2

        dense = jax.jit(count_butterflies_dense)
        tiled = jax.jit(lambda a: count_butterflies_tiled(a, tile=512))
        jax.block_until_ready(dense(adj)); jax.block_until_ready(tiled(adj))
        for name, fn in [("dense", dense), ("tiled512", tiled)]:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(adj))
            dt = time.perf_counter() - t0
            rows.append((f"kernel/{name}_{n_i}x{n_j}", dt * 1e6,
                         f"{macs / dt / 1e9:.2f} GMAC/s"))

    # -- executor dispatch per tier (bucketed window batch) --------------------
    n = 2_000 if quick else 8_000
    s = bipartite_pa_stream(n, temporal="uniform", n_unique=n // 5, seed=3)
    wb = s.windowize(60)
    tiers = ("dense", "tiled") if quick else ("dense", "tiled", "pallas")
    for tier in tiers:
        ex = WindowExecutor(tier)
        ex.window_counts(wb)  # compile buckets
        t0 = time.perf_counter()
        counts = ex.window_counts(wb)
        dt = time.perf_counter() - t0
        rows.append((f"kernel/executor_{tier}", dt * 1e6,
                     f"{wb.n_windows / dt:.0f} win/s sum={counts.sum():.0f}"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes + fewer tiers (CI smoke check)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
