"""Butterfly-kernel micro-bench: tiled-JAX vs dense-Gram vs (interpret-mode)
Pallas on window-sized biadjacencies; derived column = GMAC/s of the Gram
contraction (the kernel's roofline axis)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.butterfly import count_butterflies_dense, count_butterflies_tiled

__all__ = ["run"]


def run() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    for n_i, n_j, dens in [(1024, 2048, 0.01), (2048, 4096, 0.005)]:
        adj = jnp.asarray((rng.random((n_i, n_j)) < dens), jnp.float32)
        macs = n_i * n_i * n_j / 2

        dense = jax.jit(count_butterflies_dense)
        tiled = jax.jit(lambda a: count_butterflies_tiled(a, tile=512))
        jax.block_until_ready(dense(adj)); jax.block_until_ready(tiled(adj))
        for name, fn in [("dense", dense), ("tiled512", tiled)]:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(adj))
            dt = time.perf_counter() - t0
            rows.append((f"kernel/{name}_{n_i}x{n_j}", dt * 1e6,
                         f"{macs / dt / 1e9:.2f} GMAC/s"))
    return rows
