"""Paper Tables 8-9 + Figs 31-36: sGrapp vs FLEET throughput and MAPE.

Throughput = processed edges / elapsed wall seconds, both suites measured
host-side on the same stream (the paper measured its Java impls the same
way).  sGrapp's pipeline = windowize (host) + bucket-batched exact window
counts through the window executor + estimator; FLEET = sequential reservoir
(numpy/python).  Per-tier rows compare the executor's counting backends
(incl. ``sparse`` and the cost-model ``auto`` router) — every tier runs at
bucket capacity through the chunked-vmap dispatch, never the global
[n_i, n_j] biadjacency.  Executor rows report timeit-style best-of-5
(best-of-3 for the sharded sweep; CI runners share cores and single-shot
noise is strictly additive, so the minimum is the honest estimate), and the
``count_edges`` row covers the one-window online entry
(``adaptive_window_stream`` consumers) with its memoized per-rung counter.

``--streaming`` adds the online-ingestion sweep (:func:`run_streaming`):
the same stream pushed through :class:`repro.streams.StreamingSGrapp` at
several micro-batch sizes, so batch-replay and streaming edges/sec are
directly comparable (replayed windows and pushed windows produce
bit-identical estimates, so the delta is pure ingestion overhead).

``--multistream`` adds the multi-tenant serving sweep
(:func:`run_multistream`): N independent streams served by one
:class:`repro.streams.MultiStreamSGrapp` (tagged pushes, cross-stream
co-batched executor flushes) vs the same N streams through N sequential
dedicated single-stream engines.  Per-tenant estimates are asserted
bit-identical between the two before timing, so the rows compare pure
serving efficiency — the batched rows' win is dispatch amortization, not a
different computation.

``--devices N`` adds a device-count sweep over the executor's sharded
dispatch path (1, 2, 4, ... up to N).  On a CPU-only host pass it on the
command line — the module forces ``--xla_force_host_platform_device_count``
*before* jax initializes, which is why the flag is sniffed at import time
when run as a script.

Both sweeps emit machine-readable artifacts next to the CSV:
``BENCH_throughput.json`` and (with ``--streaming``)
``BENCH_streaming.json`` — schema in :mod:`benchmarks.artifacts`, regression
gate in :mod:`benchmarks.gate`.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    # must precede any jax import: device count locks at first jax init;
    # accept both argparse spellings, "--devices N" and "--devices=N"
    _n = 0
    for _k, _arg in enumerate(sys.argv):
        if _arg == "--devices" and _k + 1 < len(sys.argv):
            _n = int(sys.argv[_k + 1])
        elif _arg.startswith("--devices="):
            _n = int(_arg.split("=", 1)[1])
    _flags = os.environ.get("XLA_FLAGS", "")
    if _n > 1 and "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count={_n}".strip())

import time

import numpy as np

from repro.core.executor import WindowExecutor
from repro.core.fleet import fleet_run, fleet_run_chunked, reservoir_run
from repro.core.sgrapp import mape, run_sgrapp
from repro.core.windows import window_bounds
from repro.streams import (
    MultiStreamSGrapp,
    StreamingSGrapp,
    bipartite_pa_stream,
    dynamic_sgr_stream,
)

from .common import ground_truth_cumulative

__all__ = ["run", "run_streaming", "run_multistream", "run_dynamic",
           "run_fleet"]


def _timed(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def run(*, quick: bool = False, devices: int = 0) -> list[tuple]:
    rows = []
    n = 8_000 if quick else 30_000
    s = bipartite_pa_stream(n, temporal="uniform", n_unique=n // 5, seed=3)
    ntw, alpha = 120, 0.95

    # -- sGrapp throughput (Table 8 analogue) ---------------------------------
    t0 = time.perf_counter()
    wb = s.windowize(ntw)
    res = run_sgrapp(wb, alpha)
    dt = time.perf_counter() - t0
    n_processed = int(wb.cum_sgrs[-1])
    rows.append(("throughput/sgrapp_edges_per_s", dt * 1e6,
                 f"{n_processed / dt:.0f}"))
    # warm path (jit cached): streaming steady-state rate
    t0 = time.perf_counter()
    wb2 = s.windowize(ntw)
    run_sgrapp(wb2, alpha)
    dt2 = time.perf_counter() - t0
    rows.append(("throughput/sgrapp_edges_per_s_warm", dt2 * 1e6,
                 f"{n_processed / dt2:.0f}"))

    # -- executor counting tiers (bucketed capacities, no global biadjacency) --
    # timeit-style best-of-N: the CI runners share cores, and noise on a
    # single-shot timing is strictly additive — the minimum is the honest
    # estimate of the code's speed and keeps the regression gate quiet
    tiers = (("dense", "tiled", "sparse", "auto") if quick
             else ("numpy", "dense", "tiled", "sparse", "auto"))
    for tier in tiers:
        ex = WindowExecutor(tier)
        ex.run(wb)  # compile every bucket
        dte = min(_timed(ex.run, wb) for _ in range(5))
        buckets = ex.plan(wb)
        caps = "+".join(f"{b.cap_i}x{b.cap_j}x{b.n_windows}" for b in buckets)
        rows.append((f"throughput/executor_{tier}_windows_per_s", dte * 1e6,
                     f"{wb.n_windows / dte:.0f} (buckets {caps})"))

    # -- online one-window path: count_edges micro-bench -----------------------
    # covers the adaptive_window_stream per-window entry (memoized online
    # counter) with the regression gate
    exo = WindowExecutor("dense")
    k0, k1 = map(int, window_bounds(s.tau, ntw)[0])
    oe_i, oe_j = s.edge_i[k0:k1], s.edge_j[k0:k1]
    exo.count_edges(oe_i, oe_j)  # compile
    reps = 20
    t0 = time.perf_counter()
    for _ in range(reps):
        exo.count_edges(oe_i, oe_j)
    dto = (time.perf_counter() - t0) / reps
    rows.append(("throughput/count_edges_online_windows_per_s", dto * 1e6,
                 f"{1.0 / dto:.0f} ({k1 - k0} edges/window)"))

    # -- sharded dispatch sweep (scaling with device count) --------------------
    if devices > 0:
        import jax

        avail = jax.device_count()
        ks, k = [], 1
        while k <= min(devices, avail):
            ks.append(k)
            k *= 2
        if min(devices, avail) not in ks:
            ks.append(min(devices, avail))
        for k in ks:
            ex = WindowExecutor("dense", devices=k) if k > 1 else \
                WindowExecutor("dense")
            res = ex.run(wb)  # compile every bucket (per device count)
            dts = min(_timed(ex.run, wb) for _ in range(3))
            rows.append((f"throughput/sharded_dense_d{k}_windows_per_s",
                         dts * 1e6,
                         f"{wb.n_windows / dts:.0f} (shards {res.n_shards})"))

    # -- FLEET throughput ------------------------------------------------------
    for variant in (2, 3):
        for M in (2000, 8000):
            t0 = time.perf_counter()
            fleet_run(s.edge_i, s.edge_j, variant=variant, capacity=M,
                      gamma=0.7, seed=0)
            dtf = time.perf_counter() - t0
            rows.append((f"throughput/fleet{variant}_M{M}_edges_per_s",
                         dtf * 1e6, f"{len(s) / dtf:.0f}"))

    # -- accuracy comparison on a prefix (Table 9 analogue) --------------------
    prefix = s.prefix(8000)
    ntw9 = 80
    wb9 = prefix.windowize(ntw9)
    truths = ground_truth_cumulative(prefix, ntw9)
    bounds = window_bounds(prefix.tau, ntw9)
    best_sg = min(run_sgrapp(wb9, a, truths=truths).mape()
                  for a in (0.85, 0.9, 0.95, 1.0, 1.05))
    rows.append(("mape/sgrapp", 0.0, f"{best_sg:.4f}"))
    cps = bounds[:, 1]
    M = max(800, len(prefix) // 100)  # paper: M = 0.01 S
    for variant in (1, 2, 3):
        est, _ = fleet_run(prefix.edge_i, prefix.edge_j, variant=variant,
                           capacity=M, gamma=0.7, seed=0, checkpoints=cps)
        rows.append((f"mape/fleet{variant}", 0.0, f"{mape(est, truths):.4f}"))

    # -- Figs 31-36: per-window latency/throughput trace ------------------------
    ex = WindowExecutor("dense")
    ex.window_counts(wb9)  # compile
    lat = []
    for k in range(min(6, wb9.n_windows)):
        one = prefix.windowize(ntw9)
        t0 = time.perf_counter()
        ex.window_counts(one)
        lat.append((time.perf_counter() - t0) / one.n_windows)
    rows.append(("latency/per_window_s", float(np.mean(lat)) * 1e6,
                 f"mean={np.mean(lat)*1e3:.2f}ms"))
    return rows


def run_streaming(*, quick: bool = False, tier: str = "dense",
                  devices: int = 0) -> list[tuple]:
    """Online-ingestion throughput: the same stream as :func:`run`, pushed
    through the streaming engine at several micro-batch sizes.

    Per micro-batch size B the row is ``streaming/engine_{tier}_mb{B}_
    edges_per_s``; a warm batch-replay row on the identical stream anchors
    the comparison (streaming and replay estimates are bit-identical, so any
    gap is pure ingestion/dispatch overhead).  ``flush_every`` scales with B
    so small micro-batches still amortize executor dispatch.
    """
    rows = []
    n = 8_000 if quick else 30_000
    s = bipartite_pa_stream(n, temporal="uniform", n_unique=n // 5, seed=3)
    ntw, alpha = 120, 0.95
    # windows are contiguous from sgr 0, so the last close bound = |E| processed
    n_processed = int(window_bounds(s.tau, ntw)[-1, 1])

    # warm replay anchor (compile caches hot after the first run)
    run_sgrapp(s.windowize(ntw), alpha, tier=tier)
    t0 = time.perf_counter()
    run_sgrapp(s.windowize(ntw), alpha, tier=tier)
    dt = time.perf_counter() - t0
    rows.append((f"streaming/replay_{tier}_edges_per_s", dt * 1e6,
                 f"{n_processed / dt:.0f}"))

    import jax

    eng_devices = (min(devices, jax.device_count())
                   if devices > 1 and jax.device_count() > 1 else None)
    sizes = (1, 256) if quick else (1, 64, 1024)
    for mb in sizes:
        flush_every = max(4, min(64, 4096 // max(mb, 1)))

        def ingest():
            eng = StreamingSGrapp(ntw, alpha, tier=tier,
                                  flush_every=flush_every,
                                  devices=eng_devices)
            for a in range(0, len(s), mb):
                eng.push(s.tau[a:a + mb], s.edge_i[a:a + mb],
                         s.edge_j[a:a + mb])
            return eng.finalize()

        ingest()  # warm every bucket shape this stream produces
        t0 = time.perf_counter()
        res = ingest()
        dts = time.perf_counter() - t0
        rows.append((f"streaming/engine_{tier}_mb{mb}_edges_per_s",
                     dts * 1e6,
                     f"{n_processed / dts:.0f} (flush_every={flush_every}, "
                     f"{len(res.estimates)} windows)"))
    return rows


def run_multistream(*, quick: bool = False, tier: str = "dense",
                    devices: int = 0, n_streams: int = 4) -> list[tuple]:
    """Multi-tenant serving throughput: N independent streams through one
    :class:`MultiStreamSGrapp` vs N sequential dedicated single-stream
    engines on the identical streams.

    The ingestion shape is a serving frontend's: one tagged wire stream,
    records of all tenants interleaved round-robin, arriving in fixed-size
    micro-batches.  The fleet ingests each tagged batch with one ``push``;
    the dedicated engines each get their tenant's records carved out.  Both
    run at ``flush_every=1`` — the lowest-latency setting, where every
    closed window must be counted as soon as its batch arrives — which is
    where co-batching is structural: the fleet counts ALL tenants' windows
    that closed in a batch in ONE bucketed dispatch, the dedicated engines
    pay one dispatch per tenant.  (At large ``flush_every`` both schedules
    amortize dispatch and converge; the latency-throughput trade-off is the
    single-stream engine's ``flush_every`` doc.)

    Rows are ``multistream/batched_{tier}_n{N}_windows_per_s`` and
    ``multistream/sequential_{tier}_n{N}_windows_per_s`` (us = total wall
    time, derived = aggregate closed-windows/s) plus an untimed
    ``multistream/batched_speedup_...`` row carrying the ratio.  The warmup
    pass asserts every tenant's estimates are bit-identical between the two
    schedules, so the comparison is apples-to-apples by construction.
    """
    rows = []
    n = 8_000 if quick else 20_000
    ntw, alpha, mb, flush_every = 120, 0.95, 256, 1
    streams = [bipartite_pa_stream(n, temporal="uniform", n_unique=n // 5,
                                   seed=3 + s) for s in range(n_streams)]
    # one tagged wire stream: all tenants' records, round-robin interleaved
    sid = np.concatenate([np.full(len(s), k, dtype=np.int64)
                          for k, s in enumerate(streams)])
    tau = np.concatenate([s.tau for s in streams])
    ei = np.concatenate([s.edge_i for s in streams])
    ej = np.concatenate([s.edge_j for s in streams])
    order = np.argsort(np.concatenate([np.arange(len(s)) for s in streams]),
                       kind="stable")
    sid, tau, ei, ej = sid[order], tau[order], ei[order], ej[order]

    import jax

    eng_devices = (min(devices, jax.device_count())
                   if devices > 1 and jax.device_count() > 1 else None)

    def sequential():
        out = []
        for s in streams:
            eng = StreamingSGrapp(ntw, alpha, tier=tier,
                                  flush_every=flush_every,
                                  devices=eng_devices)
            for a in range(0, len(s), mb):
                eng.push(s.tau[a:a + mb], s.edge_i[a:a + mb],
                         s.edge_j[a:a + mb])
            out.append(eng.finalize())
        return out

    def batched():
        eng = MultiStreamSGrapp(n_streams, ntw, alpha, tier=tier,
                                flush_every=flush_every, devices=eng_devices)
        step = n_streams * mb  # same records per arriving batch as N x mb
        for a in range(0, len(sid), step):
            eng.push(sid[a:a + step], tau[a:a + step], ei[a:a + step],
                     ej[a:a + step])
        return eng.finalize()

    # warm every bucket shape + pin the bit-identity contract before timing
    ref, got = sequential(), batched()
    for s in range(n_streams):
        np.testing.assert_array_equal(got[s].estimates, ref[s].estimates)
    n_windows = sum(len(r.estimates) for r in ref)

    dt_b = min(_timed(batched) for _ in range(3))
    dt_s = min(_timed(sequential) for _ in range(3))
    rows.append((f"multistream/batched_{tier}_n{n_streams}_windows_per_s",
                 dt_b * 1e6,
                 f"{n_windows / dt_b:.0f} ({n_windows} windows co-batched, "
                 f"flush_every={flush_every})"))
    rows.append((f"multistream/sequential_{tier}_n{n_streams}_windows_per_s",
                 dt_s * 1e6,
                 f"{n_windows / dt_s:.0f} ({n_streams} dedicated engines)"))
    rows.append((f"multistream/batched_speedup_{tier}_n{n_streams}", 0.0,
                 f"{dt_s / dt_b:.2f}x"))
    return rows


def run_dynamic(*, quick: bool = False, tier: str = "dense",
                devices: int = 0) -> list[tuple]:
    """Dynamic wire-format ingestion throughput: one engine fed an
    insert-only stream (the static fast path), a 10%-delete stream, and a
    duplicate-heavy stream under the multiset policy.

    Per scenario the row is ``dynamic/engine_{tier}_{scenario}_edges_per_s``
    (records per second through push+flush, end-tau-aligned streams from the
    same generator).  The insert-only row anchors the comparison: the gap to
    it prices the op lane, the per-window delete resolution, and (for the
    duplicate-heavy row) the multiplicity-weighted counting tiers.
    """
    rows = []
    n = 6_000 if quick else 20_000
    ntw, mb = 60, 256
    scenarios = (
        ("insert_only", dict(delete_frac=0.0, dup_frac=0.0), "distinct"),
        ("del10", dict(delete_frac=0.10, dup_frac=0.05), "distinct"),
        ("dup_heavy", dict(delete_frac=0.05, dup_frac=0.5), "multiset"),
    )
    import jax

    eng_devices = (min(devices, jax.device_count())
                   if devices > 1 and jax.device_count() > 1 else None)
    for name, kw, policy in scenarios:
        tau, ei, ej, op = dynamic_sgr_stream(n, ntw, n_i=400, n_j=400,
                                             seed=17, **kw)
        wire_op = op if op.any() else None

        def ingest():
            eng = StreamingSGrapp(ntw, 0.95, tier=tier, flush_every=16,
                                  devices=eng_devices, dup_policy=policy)
            for a in range(0, tau.size, mb):
                sl = slice(a, a + mb)
                eng.push(tau[sl], ei[sl], ej[sl],
                         op=None if wire_op is None else wire_op[sl])
            return eng.finalize()

        ingest()  # warm every bucket shape this stream produces
        t0 = time.perf_counter()
        res = ingest()
        dt = time.perf_counter() - t0
        rows.append((f"dynamic/engine_{tier}_{name}_edges_per_s", dt * 1e6,
                     f"{n / dt:.0f} ({len(res.estimates)} windows, "
                     f"policy={policy})"))
    return rows


def run_fleet(*, quick: bool = False) -> list[tuple]:
    """FLEET sampling sweep: the per-edge Python reservoirs vs the jitted
    vectorized reservoir (:func:`repro.core.fleet.reservoir_run`) vs the
    ``sampled`` executor tier ingesting through the streaming engine.

    Throughput rows (same edges, same capacity M, same gamma):

    - ``fleet/python_v3_M{M}_edges_per_s`` — :func:`fleet_run`, the paper
      baseline's sequential per-edge loop,
    - ``fleet/chunked_v3_M{M}_edges_per_s`` — :func:`fleet_run_chunked`,
      the numpy micro-batched variant of the same loop,
    - ``fleet/reservoir_M{M}_edges_per_s`` — the jitted content-keyed
      reservoir scan (best-of-3 after compile),
    - ``fleet/engine_sampled_mb256_edges_per_s`` — end-to-end online
      ingestion through :class:`StreamingSGrapp` on the ``sampled`` tier.

    Derived rows: ``fleet/speedup_reservoir_vs_python`` and
    ``fleet/speedup_sampled_engine_vs_python`` (edges/s ratios — the
    tentpole's >= 10x target is the reservoir row), plus accuracy rows
    ``mape/fleet_reservoir_M{M}`` (jitted reservoir's final-estimate
    relative error vs the exact count) and ``mape/sampled_tier_M{M}``
    (sampled-tier window counts' mean relative error vs the dense tier on
    the identical stream) whose derived field is the bare float the
    regression gate reads.
    """
    rows = []
    # larger than the other quick sweeps: the jitted reservoir's edge rate
    # climbs with stream length (fixed dispatch overhead amortizes) while
    # the python loop's rate is flat, so the speedup row needs enough edges
    # to measure the asymptotic ratio rather than dispatch constants
    n = 16_000 if quick else 40_000
    s = bipartite_pa_stream(n, temporal="uniform", n_unique=n // 5, seed=3)
    ntw = 120
    M = 1024 if quick else 4096
    gamma = 0.7

    # -- paper-baseline reservoirs: sequential python, then numpy-chunked ----
    t0 = time.perf_counter()
    est_py, _ = fleet_run(s.edge_i, s.edge_j, variant=3, capacity=M,
                          gamma=gamma, seed=0)
    dt_py = time.perf_counter() - t0
    rows.append((f"fleet/python_v3_M{M}_edges_per_s", dt_py * 1e6,
                 f"{len(s) / dt_py:.0f}"))
    t0 = time.perf_counter()
    fleet_run_chunked(s.edge_i, s.edge_j, variant=3, capacity=M,
                      gamma=gamma, seed=0)
    dt_ch = time.perf_counter() - t0
    rows.append((f"fleet/chunked_v3_M{M}_edges_per_s", dt_ch * 1e6,
                 f"{len(s) / dt_ch:.0f}"))

    # -- jitted vectorized reservoir (tentpole) ------------------------------
    est_res, _ = reservoir_run(s.edge_i, s.edge_j, capacity=M, gamma=gamma,
                               seed=0)  # compile + record the estimate
    def _res_once():
        reservoir_run(s.edge_i, s.edge_j, capacity=M, gamma=gamma, seed=0)

    dt_res = min(_timed(_res_once) for _ in range(3))
    rows.append((f"fleet/reservoir_M{M}_edges_per_s", dt_res * 1e6,
                 f"{len(s) / dt_res:.0f}"))
    rows.append(("fleet/speedup_reservoir_vs_python", 0.0,
                 f"{dt_py / dt_res:.1f}"))

    # -- sampled-tier online ingestion (windows, estimator, the works) ------
    # per-window capacity sits below the typical window edge count so the
    # timed path exercises real subsampling, not the degenerate shortcut
    mb = 256
    M_tier = 256
    n_processed = int(window_bounds(s.tau, ntw)[-1, 1])

    def ingest(tier):
        ex = (WindowExecutor("sampled", snap=0, capacity=M_tier, gamma=gamma,
                             seed=0) if tier == "sampled"
              else WindowExecutor(tier, snap=0))
        eng = StreamingSGrapp(ntw, 0.95, tier=tier, executor=ex,
                              flush_every=16)
        for a in range(0, len(s), mb):
            eng.push(s.tau[a:a + mb], s.edge_i[a:a + mb], s.edge_j[a:a + mb])
        return eng.finalize()

    ingest("sampled")  # warm every bucket shape this stream produces
    t0 = time.perf_counter()
    res_samp = ingest("sampled")
    dt_eng = time.perf_counter() - t0
    rows.append((f"fleet/engine_sampled_mb{mb}_edges_per_s", dt_eng * 1e6,
                 f"{n_processed / dt_eng:.0f} "
                 f"({len(res_samp.estimates)} windows, M={M_tier})"))
    rows.append(("fleet/speedup_sampled_engine_vs_python", 0.0,
                 f"{(n_processed / dt_eng) / (len(s) / dt_py):.1f}"))

    # -- accuracy: bare-float derived values for the mape regression gate ---
    # mean absolute relative error over a fixed seed set — single-seed
    # reservoir estimates are high-variance by design (p**-4 scaling), the
    # seed-averaged error is the stable pinnable number
    from repro.core.butterfly import count_butterflies_np

    exact = count_butterflies_np(np.stack([s.edge_i, s.edge_j], axis=1))
    errs = [abs(est_res - exact) / max(exact, 1)]
    for sd in range(1, 8):
        e, _ = reservoir_run(s.edge_i, s.edge_j, capacity=M, gamma=gamma,
                             seed=sd)
        errs.append(abs(e - exact) / max(exact, 1))
    rows.append((f"mape/fleet_reservoir_M{M}", 0.0,
                 f"{float(np.mean(errs)):.4f}"))
    res_dense = ingest("dense")
    wc_e = res_dense.window_counts
    wc_s = res_samp.window_counts
    mask = wc_e > 0
    err_tier = (float(np.mean(np.abs(wc_s[mask] - wc_e[mask]) / wc_e[mask]))
                if mask.any() else 0.0)
    rows.append((f"mape/sampled_tier_M{M_tier}", 0.0, f"{err_tier:.4f}"))
    return rows


def run_latency(*, quick: bool = False, tier: str = "dense") -> list[tuple]:
    """Latency sweep for the async overlapped flush pipeline (ROADMAP: the
    mb=1 gap): first-window latency with and without the pre-traced rung
    ladder (``EngineConfig.warmup``), mb=1 ingestion on the async default vs
    the ``sync_dispatch`` escape hatch vs mb=256, and flush_every=1
    multi-stream counting cost per push call.

    The gated row is ``latency/mb1_vs_mb256_ratio`` (bare-float derived,
    lower is better): how many times slower per-record ingestion is than
    big-batch ingestion on the identical stream.  ``benchmarks.gate`` holds
    it to the committed baseline, which pins the "mb=1 within ~4x of
    mb=256" acceptance target.
    """
    rows = []
    n = 8_000 if quick else 30_000
    s = bipartite_pa_stream(n, temporal="uniform", n_unique=n // 5, seed=3)
    ntw, alpha = 120, 0.95
    n_processed = int(window_bounds(s.tau, ntw)[-1, 1])

    from repro.streams.config import EngineConfig

    # -- first-window latency: cold trace+compile vs pre-traced rungs --------
    # discover the stream's rung ladder with a numpy-tier probe (numpy
    # never compiles), recording every bucket the executor plans
    probe = StreamingSGrapp(ntw, alpha,
                            config=EngineConfig(tier="numpy", flush_every=1))
    rungs: set = set()
    orig_submit = probe.executor.window_counts_submit

    def recording(batch):
        rungs.update((b.cap_e, b.cap_i, b.cap_j)
                     for b in probe.executor.plan(batch))
        return orig_submit(batch)

    probe.executor.window_counts_submit = recording
    probe.push(s.tau, s.edge_i, s.edge_j)
    probe.finalize()

    def first_window_ms(warmup: tuple) -> float:
        # construction (incl. warmup compile) is OUTSIDE the timed span:
        # warmup's point is moving trace+compile out of the latency path
        eng = StreamingSGrapp(ntw, alpha, config=EngineConfig(
            tier=tier, flush_every=1, warmup=warmup))
        t0 = time.perf_counter()
        for a in range(0, len(s), 64):
            eng.push(s.tau[a:a + 64], s.edge_i[a:a + 64],
                     s.edge_j[a:a + 64])
            if eng.n_windows >= 1:
                eng.flush()   # reap: the estimate is materialized
                break
        return (time.perf_counter() - t0) * 1e3

    # run the no-warmup leg first: in a fresh process (the CI leg) the
    # tier's rungs are genuinely cold here
    cold_ms = first_window_ms(())
    warm_ms = first_window_ms(tuple(sorted(rungs)))
    rows.append(("latency/first_window_ms_no_warmup", cold_ms * 1e3,
                 f"{cold_ms:.1f}ms"))
    rows.append(("latency/first_window_ms_with_warmup", warm_ms * 1e3,
                 f"{warm_ms:.1f}ms"))

    # -- mb=1 vs mb=256 ingestion (async default vs sync_dispatch) ----------
    def ingest(mb: int, sync: bool):
        flush_every = max(4, min(64, 4096 // max(mb, 1)))
        eng = StreamingSGrapp(ntw, alpha, config=EngineConfig(
            tier=tier, flush_every=flush_every, sync_dispatch=sync))
        for a in range(0, len(s), mb):
            eng.push(s.tau[a:a + mb], s.edge_i[a:a + mb],
                     s.edge_j[a:a + mb])
        return eng.finalize()

    rates = {}
    for name, mb, sync in (("streaming_mb1", 1, False),
                           ("streaming_mb1_sync", 1, True),
                           ("streaming_mb256", 256, False)):
        ingest(mb, sync)   # warm every bucket shape this stream produces
        dt = min(_timed(lambda: ingest(mb, sync)) for _ in range(2))
        rates[name] = n_processed / dt
        rows.append((f"latency/{name}_edges_per_s", dt * 1e6,
                     f"{rates[name]:.0f}"))
    ratio = rates["streaming_mb256"] / rates["streaming_mb1"]
    rows.append(("latency/mb1_vs_mb256_ratio", 0.0, f"{ratio:.4f}"))

    # -- flush_every=1 multi-stream counting, ms per push call --------------
    n_streams, mb = 4, 256
    streams = [bipartite_pa_stream(n, temporal="uniform", n_unique=n // 5,
                                   seed=3 + k) for k in range(n_streams)]
    sid = np.concatenate([np.full(len(t), k, dtype=np.int64)
                          for k, t in enumerate(streams)])
    tau = np.concatenate([t.tau for t in streams])
    ei = np.concatenate([t.edge_i for t in streams])
    ej = np.concatenate([t.edge_j for t in streams])
    order = np.argsort(np.concatenate([np.arange(len(t)) for t in streams]),
                       kind="stable")
    sid, tau, ei, ej = sid[order], tau[order], ei[order], ej[order]
    step = n_streams * mb
    n_calls = (len(sid) + step - 1) // step

    def fleet_ingest():
        eng = MultiStreamSGrapp(n_streams, ntw, alpha, config=EngineConfig(
            tier=tier, flush_every=1))
        for a in range(0, len(sid), step):
            eng.push(sid[a:a + step], tau[a:a + step], ei[a:a + step],
                     ej[a:a + step])
        return eng.finalize()

    fleet_ingest()   # warm
    dt = min(_timed(fleet_ingest) for _ in range(2))
    ms_per_call = dt / n_calls * 1e3
    rows.append(("latency/multistream_flush1_ms_per_call", ms_per_call * 1e3,
                 f"{ms_per_call:.2f}ms (n_streams={n_streams}, mb={mb}, "
                 f"{n_calls} calls)"))
    return rows


def main() -> None:
    import argparse

    from .artifacts import write_bench_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller stream (CI smoke check)")
    ap.add_argument("--devices", type=int, default=0,
                    help="sweep the sharded executor over 1..N devices "
                         "(forces N virtual host devices on CPU)")
    ap.add_argument("--streaming", action="store_true",
                    help="add the online micro-batch ingestion sweep "
                         "(StreamingSGrapp push path)")
    ap.add_argument("--streaming-only", action="store_true",
                    help="skip the base throughput sweep (for per-tier "
                         "streaming legs in CI: implies --streaming)")
    ap.add_argument("--multistream", action="store_true",
                    help="add the multi-tenant serving sweep (N streams "
                         "batched through one MultiStreamSGrapp vs N "
                         "sequential dedicated engines)")
    ap.add_argument("--multistream-only", action="store_true",
                    help="run only the multi-tenant sweep (CI leg: implies "
                         "--multistream, skips the other sweeps)")
    ap.add_argument("--dynamic", action="store_true",
                    help="add the dynamic wire-format sweep (insert-only vs "
                         "10%%-delete vs duplicate-heavy ingestion)")
    ap.add_argument("--dynamic-only", action="store_true",
                    help="run only the dynamic sweep (CI leg: implies "
                         "--dynamic, skips the other sweeps)")
    ap.add_argument("--fleet", action="store_true",
                    help="add the FLEET sampling sweep (python reservoirs "
                         "vs the jitted reservoir vs the sampled tier)")
    ap.add_argument("--fleet-only", action="store_true",
                    help="run only the FLEET sampling sweep (CI leg: "
                         "implies --fleet, skips the other sweeps)")
    ap.add_argument("--latency", action="store_true",
                    help="add the async-flush latency sweep (first-window "
                         "latency with/without warmup, mb=1 vs mb=256 "
                         "ingestion, flush_every=1 multi-stream ms/call)")
    ap.add_argument("--latency-only", action="store_true",
                    help="run only the latency sweep (CI leg: implies "
                         "--latency, skips the other sweeps)")
    ap.add_argument("--tier", default="dense",
                    help="counting tier for the streaming sweep "
                         "(numpy | dense | tiled | pallas | sparse | auto)")
    ap.add_argument("--artifact-suffix", default="",
                    help="suffix for the BENCH_*.json filenames, e.g. "
                         "'_sparse' -> BENCH_streaming_sparse.json (lets "
                         "per-tier CI legs upload side by side)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing BENCH_*.json artifacts")
    args = ap.parse_args()
    sfx = args.artifact_suffix
    print("name,us_per_call,derived")
    if not (args.streaming_only or args.multistream_only
            or args.dynamic_only or args.fleet_only or args.latency_only):
        rows = run(quick=args.quick, devices=args.devices)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        if not args.no_json:
            write_bench_json(f"BENCH_throughput{sfx}.json", rows,
                             devices=args.devices, quick=args.quick)
    if ((args.streaming or args.streaming_only)
            and not (args.multistream_only or args.dynamic_only
                     or args.fleet_only or args.latency_only)):
        srows = run_streaming(quick=args.quick, tier=args.tier,
                              devices=args.devices)
        for name, us, derived in srows:
            print(f"{name},{us:.1f},{derived}")
        if not args.no_json:
            write_bench_json(f"BENCH_streaming{sfx}.json", srows,
                             devices=args.devices, quick=args.quick)
    if ((args.multistream or args.multistream_only)
            and not (args.dynamic_only or args.fleet_only
                     or args.latency_only)):
        mrows = run_multistream(quick=args.quick, tier=args.tier,
                                devices=args.devices)
        for name, us, derived in mrows:
            print(f"{name},{us:.1f},{derived}")
        if not args.no_json:
            write_bench_json(f"BENCH_multistream{sfx}.json", mrows,
                             devices=args.devices, quick=args.quick)
    if ((args.dynamic or args.dynamic_only)
            and not (args.fleet_only or args.latency_only)):
        drows = run_dynamic(quick=args.quick, tier=args.tier,
                            devices=args.devices)
        for name, us, derived in drows:
            print(f"{name},{us:.1f},{derived}")
        if not args.no_json:
            write_bench_json(f"BENCH_dynamic{sfx}.json", drows,
                             devices=args.devices, quick=args.quick)
    if (args.fleet or args.fleet_only) and not args.latency_only:
        frows = run_fleet(quick=args.quick)
        for name, us, derived in frows:
            print(f"{name},{us:.1f},{derived}")
        if not args.no_json:
            write_bench_json(f"BENCH_fleet{sfx}.json", frows,
                             devices=args.devices, quick=args.quick)
    if args.latency or args.latency_only:
        lrows = run_latency(quick=args.quick, tier=args.tier)
        for name, us, derived in lrows:
            print(f"{name},{us:.1f},{derived}")
        if not args.no_json:
            write_bench_json(f"BENCH_latency{sfx}.json", lrows,
                             devices=args.devices, quick=args.quick)


if __name__ == "__main__":
    main()
