"""Paper Table 3 + Figs 5-6: temporal butterfly growth, polynomial fits,
and the butterfly densification power law (eta > 1 on real-like streams)."""
from __future__ import annotations

import time

from repro.core.analysis import butterfly_growth_curve, fit_polynomials, fit_power_law

from .common import bench_streams

__all__ = ["run"]


def run() -> list[tuple]:
    rows = []
    for name, s in bench_streams().items():
        t0 = time.perf_counter()
        t, b = butterfly_growth_curve(s.edge_i, s.edge_j, max_edges=2500, stride=100)
        fits = fit_polynomials(t, b)
        eta, c, r2 = fit_power_law(t, b)
        dt = (time.perf_counter() - t0) * 1e6
        best = max((f for f in fits if f.increasing), key=lambda f: f.r2,
                   default=max(fits, key=lambda f: f.r2))
        rows.append((f"densification/{name}/eta", dt,
                     f"eta={eta:.3f} r2={r2:.3f}"))
        rows.append((f"densification/{name}/best_poly", dt,
                     f"deg={best.degree} r2={best.r2:.4f} rmse={best.rmse:.3g}"))
        rows.append((f"densification/{name}/B_final", dt, f"{b[-1]:.0f}"))
    return rows
