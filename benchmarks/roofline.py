"""Roofline analysis over the dry-run artifacts (assignment deliverable g).

Per (arch x shape) on the single-pod mesh:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs            [s]
  memory term     = HLO_bytes_per_device / HBM_bandwidth         [s]
  collective term = collective_bytes_per_device / ICI_bandwidth  [s]

cost_analysis() reports the *per-device* partitioned program, so terms are
per-chip directly.  collective bytes come from the optimized HLO (dryrun.py
sums result-shape bytes of every collective op) — also per device.

MODEL_FLOPS / (HLO_FLOPs x chips) is the useful-compute ratio (catching
remat / dispatch-dead-compute waste; remat targets ~1/3 extra fwd).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9   # v5e

__all__ = ["load_cells", "roofline_row", "roofline_table", "main"]


def load_cells(dryrun_dir: str = "experiments/dryrun", mesh: str = "pod") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, mesh, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def roofline_row(rec: dict) -> dict | None:
    if rec["status"] != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec["status"],
                "reason": rec.get("reason", rec.get("error", ""))[:90]}
    n_dev = rec["n_devices"]
    # prefer the trip-count-aware HLO cost model (launch/hlo_cost.py) —
    # XLA's cost_analysis counts while bodies once (see EXPERIMENTS.md)
    if "hlo" in rec:
        flops = rec["hlo"]["flops"] or 0.0
        bytes_acc = rec["hlo"]["bytes"] or 0.0
        coll = rec["hlo"]["collectives"].get("total", 0)
    else:
        flops = rec["cost"].get("flops", 0.0) or 0.0
        bytes_acc = rec["cost"].get("bytes accessed", 0.0) or 0.0
        coll = rec["collectives"].get("total", 0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_acc / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    mem = rec["memory"]
    hbm = (mem.get("argument_size_bytes") or 0) + (mem.get("temp_size_bytes") or 0)
    # TPU-corrected HBM: subtract the CPU-backend f32 promotion copies of
    # bf16 weights/caches (hoisted + per-loop-iteration converts; neither
    # exists on TPU where bf16 matmul is native)
    promoted = (rec.get("hlo", {}).get("promoted_f32_bytes", 0.0)
                + rec.get("hlo", {}).get("promoted_f32_loop_bytes", 0.0))
    hbm_tpu = max(hbm - promoted, 0.0)
    useful = rec.get("model_flops", 0.0) / (flops * n_dev) if flops else 0.0
    # roofline fraction: useful model flops per chip-second at the bound
    step_time = max(t_c, t_m, t_x)
    ach_flops = rec.get("model_flops", 0.0) / n_dev / max(step_time, 1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "kind": rec["kind"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom[0],
        "useful_flops_ratio": useful,
        "mfu_bound": ach_flops / PEAK_FLOPS,
        "hbm_gb": hbm_tpu / 1e9,
        "hbm_raw_gb": hbm / 1e9,
        "fits_hbm": hbm_tpu <= HBM_PER_CHIP,
    }


def roofline_table(dryrun_dir: str = "experiments/dryrun", mesh: str = "pod") -> list[dict]:
    return [roofline_row(r) for r in load_cells(dryrun_dir, mesh)]


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':<18}{'shape':<15}{'t_comp':>9}{'t_mem':>9}{'t_coll':>9}"
           f"{'dominant':>11}{'useful':>8}{'MFU≤':>7}{'HBM(GB)':>9}{'fits':>6}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r is None:
            continue
        if r["status"] != "ok":
            lines.append(f"{r['arch']:<18}{r['shape']:<15}  [{r['status']}] {r.get('reason','')}")
            continue
        lines.append(
            f"{r['arch']:<18}{r['shape']:<15}"
            f"{r['t_compute_s']*1e3:>8.2f}m{r['t_memory_s']*1e3:>8.2f}m"
            f"{r['t_collective_s']*1e3:>8.2f}m"
            f"{r['dominant']:>11}{r['useful_flops_ratio']:>8.2f}"
            f"{r['mfu_bound']:>7.2f}{r['hbm_gb']:>9.1f}"
            f"{'Y' if r['fits_hbm'] else 'N':>6}")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = roofline_table(args.dir, args.mesh)
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(format_table(rows))


if __name__ == "__main__":
    main()
