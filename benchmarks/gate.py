"""Bench regression gate: fail CI on a per-row slowdown vs the committed baseline.

Usage::

    python -m benchmarks.gate BENCH_throughput.json            # gate
    python -m benchmarks.gate BENCH_throughput.json --update-baseline

Compares each row's ``us_per_call`` against ``benchmarks/baseline.json`` by
row name and exits non-zero if any row is more than ``--max-slowdown`` times
slower (default 2x — wide enough for CI-runner noise, tight enough to catch
a lost compile cache or an accidentally serialized dispatch).  Rows missing
from the baseline (new benches) and rows with non-positive timings are
skipped for the slowdown check, so adding a bench never breaks the gate;
refreshing the committed numbers is one command away.

Quality rows — names under ``mape/...`` or ``latency/..._ratio``, timing 0,
the measured error (or lower-is-better ratio) in the ``derived`` field —
gate on *regression* instead of slowdown: when the baseline entry recorded
a ``mape`` value, a fresh value beyond ``--max-mape-ratio`` times the
baseline (plus a small absolute slack for noise) fails the gate.  The
latency ratio rows ride this path so a re-serialized async dispatch (mb=1
rate collapsing back toward the old 30x gap) fails CI the same way an
accuracy regression does.  Baseline entries without a recorded value
(legacy rows, or derived values that aren't a bare float) never gate.

Rows present in the fresh run but missing from the baseline (a new bench or
a new tier leg) are *reported* as ``new row`` — visible in the CI log so a
fresh ``--update-baseline`` commit is an informed decision — but never fail
the gate.

The reverse direction **does** fail the gate: a baseline row that this
fresh run was expected to produce but didn't is a ``stale row`` — a bench
leg that silently stopped running (a renamed row, a dropped sweep, an
early-exiting bench) would otherwise pass CI forever.  Expectation is
scoped by provenance: ``--update-baseline`` records which ``BENCH_*.json``
file (and which bench mode, quick vs full) contributed each row, so gating
``BENCH_streaming.json`` never demands rows that only the throughput or
per-tier legs produce.  Legacy baseline entries (bare numbers, no recorded
source) gate on slowdown only and are never stale-checked.

``--update-baseline`` rewrites the baseline from the fresh JSON instead of
gating — dropping this file's now-stale rows and recording provenance for
the fresh ones (commit the result; see README "Benchmark artifacts and the
regression gate").
"""
from __future__ import annotations

import argparse
import json
import os

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")

__all__ = ["gate", "new_rows", "stale_rows", "update_baseline"]


def _load_rows(path: str) -> dict[str, float]:
    """BENCH_*.json records keyed on name plus bench mode — quick and full
    runs share row names but time differently sized streams, so they gate
    against separate baseline entries."""
    with open(path) as f:
        records = json.load(f)
    return {
        r["name"] + ("@quick" if r.get("meta", {}).get("quick") else ""):
            float(r["us_per_call"])
        for r in records
    }


def _is_quality_row(name: str) -> bool:
    """Lower-is-better quality rows: accuracy (``mape/...``) plus latency
    ratios (``latency/..._ratio`` — e.g. mb=1 vs mb=256 ingestion rate).
    Deliberately narrow: other bare-float derived rows (``fleet/speedup_*``)
    are higher-is-better and must never gate through this path."""
    return name.startswith("mape/") or (
        name.startswith("latency/") and name.endswith("_ratio"))


def _load_mapes(path: str) -> dict[str, float]:
    """Quality rows of a fresh BENCH_*.json: ``mape/...`` and
    ``latency/..._ratio`` names whose ``derived`` field is a bare float (the
    measured error or ratio, lower is better), keyed like
    :func:`_load_rows`.  Rows whose derived carries annotations beyond the
    number are skipped — only purpose-built quality rows gate."""
    with open(path) as f:
        records = json.load(f)
    out = {}
    for r in records:
        if not _is_quality_row(str(r["name"])):
            continue
        try:
            val = float(str(r.get("derived", "")).strip())
        except ValueError:
            continue
        key = r["name"] + ("@quick" if r.get("meta", {}).get("quick") else "")
        out[key] = val
    return out


def _load_baseline(path: str) -> dict[str, dict]:
    """Normalized baseline entries ``{key: {"us": float, "source": str|None}}``.

    Two on-disk value formats coexist: a bare number (legacy, provenance
    unknown — gated on slowdown, never stale-checked) and
    ``{"us_per_call": ..., "source": "BENCH_xxx.json"}`` (written by
    ``--update-baseline`` since the stale-row check landed)."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        base = json.load(f)
    out = {}
    for key, val in base.items():
        if isinstance(val, dict):
            out[key] = {"us": float(val["us_per_call"]),
                        "source": val.get("source"),
                        "mape": (float(val["mape"])
                                 if "mape" in val else None)}
        else:
            out[key] = {"us": float(val), "source": None, "mape": None}
    return out


def _dump_baseline(entries: dict[str, dict], path: str) -> None:
    def _disk_entry(e):
        if e["source"] is None and e.get("mape") is None:
            return e["us"]
        d = {"us_per_call": e["us"], "source": e["source"]}
        if e.get("mape") is not None:
            d["mape"] = e["mape"]
        return d

    disk = {key: _disk_entry(e) for key, e in entries.items()}
    with open(path, "w") as f:
        json.dump(dict(sorted(disk.items())), f, indent=1)
        f.write("\n")


def _fresh_mode(fresh: dict[str, float]) -> bool | None:
    """Whether the fresh run is a quick run (every row of one run shares the
    mode); None when the file has no rows."""
    for key in fresh:
        return key.endswith("@quick")
    return None


def update_baseline(fresh_path: str, baseline_path: str = DEFAULT_BASELINE) -> str:
    """Rewrite the committed baseline (name -> us_per_call + source) from a
    fresh ``BENCH_*.json``; merges over existing entries so multiple bench
    files can contribute rows, and drops entries this file previously
    contributed (same source, same mode) that the fresh run no longer
    produces — the baseline twin of the stale-row check."""
    base = _load_baseline(baseline_path)
    fresh = _load_rows(fresh_path)
    mapes = _load_mapes(fresh_path)
    source = os.path.basename(fresh_path)
    quick = _fresh_mode(fresh)
    base = {
        key: e for key, e in base.items()
        if not (e["source"] == source
                and key.endswith("@quick") == quick
                and key not in fresh)
    }
    for key, us in fresh.items():
        base[key] = {"us": us, "source": source, "mape": mapes.get(key)}
    _dump_baseline(base, baseline_path)
    return baseline_path


def new_rows(fresh_path: str, baseline_path: str = DEFAULT_BASELINE
             ) -> list[str]:
    """Timed rows in the fresh run with no baseline entry (new benches or
    new tier legs).  These never gate — they are surfaced so the operator
    knows the baseline is due an ``--update-baseline`` refresh."""
    fresh = _load_rows(fresh_path)
    base = _load_baseline(baseline_path)
    return [name for name, us in sorted(fresh.items())
            if us > 0 and name not in base]


def stale_rows(fresh_path: str, baseline_path: str = DEFAULT_BASELINE
               ) -> list[str]:
    """Baseline rows this fresh run was expected to produce but didn't: the
    recorded source file matches, the bench mode (quick vs full) matches,
    and the row is absent from the fresh run.  A silently dropped bench leg
    shows up here instead of vanishing from CI unnoticed."""
    fresh = _load_rows(fresh_path)
    base = _load_baseline(baseline_path)
    source = os.path.basename(fresh_path)
    quick = _fresh_mode(fresh)
    return sorted(
        key for key, e in base.items()
        if e["source"] == source
        and (quick is None or key.endswith("@quick") == quick)
        and key not in fresh)


def gate(fresh_path: str, baseline_path: str = DEFAULT_BASELINE,
         *, max_slowdown: float = 2.0, max_mape_ratio: float = 1.5,
         mape_slack: float = 0.02) -> list[str]:
    """Returns the list of violation messages (empty = gate passes):
    per-row slowdowns beyond ``max_slowdown``, accuracy regressions beyond
    ``max_mape_ratio`` x baseline + ``mape_slack`` on ``mape/...`` rows the
    baseline recorded an error for, plus stale rows (baseline rows this
    file was expected to reproduce but didn't)."""
    fresh = _load_rows(fresh_path)
    mapes = _load_mapes(fresh_path)
    base = _load_baseline(baseline_path)
    violations = []
    for name, us in sorted(fresh.items()):
        entry = base.get(name)
        if entry is None or entry["us"] <= 0 or us <= 0:
            continue  # new row or non-timing row: never gates on slowdown
        ratio = us / entry["us"]
        if ratio > max_slowdown:
            violations.append(
                f"{name}: {us:.1f}us vs baseline {entry['us']:.1f}us "
                f"({ratio:.2f}x > {max_slowdown:.1f}x)")
    for name, err in sorted(mapes.items()):
        entry = base.get(name)
        if entry is None or entry.get("mape") is None:
            continue  # baseline never recorded an error: no accuracy gate
        bound = entry["mape"] * max_mape_ratio + mape_slack
        if err > bound:
            violations.append(
                f"{name}: mape {err:.4f} vs baseline {entry['mape']:.4f} "
                f"(> {max_mape_ratio:.1f}x + {mape_slack:.2f} slack "
                f"= {bound:.4f})")
    for name in stale_rows(fresh_path, baseline_path):
        violations.append(
            f"{name}: stale row — in baseline (source "
            f"{os.path.basename(fresh_path)}) but missing from the fresh "
            f"run; dropped bench leg, or refresh with --update-baseline")
    return violations


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="fresh BENCH_*.json to gate")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--max-slowdown", type=float, default=2.0)
    ap.add_argument("--max-mape-ratio", type=float, default=1.5,
                    help="accuracy rows fail when fresh mape exceeds this "
                         "multiple of the baseline mape (plus --mape-slack)")
    ap.add_argument("--mape-slack", type=float, default=0.02,
                    help="absolute mape slack added to the ratio bound so "
                         "near-zero baselines don't flap on sampling noise")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the fresh run instead "
                         "of gating")
    args = ap.parse_args()
    if args.update_baseline:
        path = update_baseline(args.fresh, args.baseline)
        print(f"baseline updated: {path}")
        return
    violations = gate(args.fresh, args.baseline,
                      max_slowdown=args.max_slowdown,
                      max_mape_ratio=args.max_mape_ratio,
                      mape_slack=args.mape_slack)
    fresh = _load_rows(args.fresh)
    gated = sum(1 for us in fresh.values() if us > 0)
    fresh_only = new_rows(args.fresh, args.baseline)
    for name in fresh_only:
        print(f"bench-gate: new row (not in baseline, not gated): {name}")
    gated -= len(fresh_only)
    if violations:
        print(f"bench-gate: {len(violations)} violation(s) "
              f"(of {gated} gated rows):")
        for v in violations:
            print(f"  {v}")
        raise SystemExit(1)
    print(f"bench-gate: OK ({gated} timed rows within "
          f"{args.max_slowdown:.1f}x of baseline)")


if __name__ == "__main__":
    main()
