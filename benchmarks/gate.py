"""Bench regression gate: fail CI on a per-row slowdown vs the committed baseline.

Usage::

    python -m benchmarks.gate BENCH_throughput.json            # gate
    python -m benchmarks.gate BENCH_throughput.json --update-baseline

Compares each row's ``us_per_call`` against ``benchmarks/baseline.json`` by
row name and exits non-zero if any row is more than ``--max-slowdown`` times
slower (default 2x — wide enough for CI-runner noise, tight enough to catch
a lost compile cache or an accidentally serialized dispatch).  Rows missing
from the baseline (new benches) and rows with non-positive timings (pure
accuracy rows like ``mape/...``) are skipped, so adding a bench never breaks
the gate; refreshing the committed numbers is one command away.

Rows present in the fresh run but missing from the baseline (a new bench or
a new tier leg) are *reported* as ``new row`` — visible in the CI log so a
fresh ``--update-baseline`` commit is an informed decision — but never fail
the gate.

``--update-baseline`` rewrites the baseline from the fresh JSON instead of
gating (commit the result; see README "Benchmark artifacts and the
regression gate").
"""
from __future__ import annotations

import argparse
import json
import os

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")

__all__ = ["gate", "new_rows", "update_baseline"]


def _load_rows(path: str) -> dict[str, float]:
    """BENCH_*.json records keyed on name plus bench mode — quick and full
    runs share row names but time differently sized streams, so they gate
    against separate baseline entries."""
    with open(path) as f:
        records = json.load(f)
    return {
        r["name"] + ("@quick" if r.get("meta", {}).get("quick") else ""):
            float(r["us_per_call"])
        for r in records
    }


def update_baseline(fresh_path: str, baseline_path: str = DEFAULT_BASELINE) -> str:
    """Rewrite the committed baseline (name -> us_per_call) from a fresh
    ``BENCH_*.json``; merges over existing entries so multiple bench files
    can contribute rows."""
    base: dict[str, float] = {}
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            base = json.load(f)
    base.update(_load_rows(fresh_path))
    with open(baseline_path, "w") as f:
        json.dump(dict(sorted(base.items())), f, indent=1)
        f.write("\n")
    return baseline_path


def new_rows(fresh_path: str, baseline_path: str = DEFAULT_BASELINE
             ) -> list[str]:
    """Timed rows in the fresh run with no baseline entry (new benches or
    new tier legs).  These never gate — they are surfaced so the operator
    knows the baseline is due an ``--update-baseline`` refresh."""
    fresh = _load_rows(fresh_path)
    base: dict[str, float] = {}
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            base = json.load(f)
    return [name for name, us in sorted(fresh.items())
            if us > 0 and name not in base]


def gate(fresh_path: str, baseline_path: str = DEFAULT_BASELINE,
         *, max_slowdown: float = 2.0) -> list[str]:
    """Returns the list of violation messages (empty = gate passes)."""
    fresh = _load_rows(fresh_path)
    with open(baseline_path) as f:
        base = json.load(f)
    violations = []
    for name, us in sorted(fresh.items()):
        base_us = base.get(name)
        if base_us is None or base_us <= 0 or us <= 0:
            continue  # new row or non-timing row: never gates
        ratio = us / base_us
        if ratio > max_slowdown:
            violations.append(
                f"{name}: {us:.1f}us vs baseline {base_us:.1f}us "
                f"({ratio:.2f}x > {max_slowdown:.1f}x)")
    return violations


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="fresh BENCH_*.json to gate")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--max-slowdown", type=float, default=2.0)
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the fresh run instead "
                         "of gating")
    args = ap.parse_args()
    if args.update_baseline:
        path = update_baseline(args.fresh, args.baseline)
        print(f"baseline updated: {path}")
        return
    violations = gate(args.fresh, args.baseline,
                      max_slowdown=args.max_slowdown)
    fresh = _load_rows(args.fresh)
    gated = sum(1 for us in fresh.values() if us > 0)
    fresh_only = new_rows(args.fresh, args.baseline)
    for name in fresh_only:
        print(f"bench-gate: new row (not in baseline, not gated): {name}")
    gated -= len(fresh_only)
    if violations:
        print(f"bench-gate: {len(violations)} row(s) regressed "
              f"(of {gated} gated):")
        for v in violations:
            print(f"  {v}")
        raise SystemExit(1)
    print(f"bench-gate: OK ({gated} timed rows within "
          f"{args.max_slowdown:.1f}x of baseline)")


if __name__ == "__main__":
    main()
