"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract) and writes
one machine-readable ``BENCH_<suite>.json`` per suite next to the CSV
(schema: :mod:`benchmarks.artifacts`; regression gate:
:mod:`benchmarks.gate`).  The roofline table (assignment deliverable g) is
emitted at the end when dry-run artifacts exist under experiments/dryrun/.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from . import (
        bench_accuracy,
        bench_densification,
        bench_hubs,
        bench_interarrival,
        bench_kernel,
        bench_throughput,
    )
    from .artifacts import write_bench_json

    suites = [
        ("densification", bench_densification.run),
        ("hubs", bench_hubs.run),
        ("interarrival", bench_interarrival.run),
        ("accuracy", bench_accuracy.run),
        ("throughput", bench_throughput.run),
        ("streaming", bench_throughput.run_streaming),
        ("kernel", bench_kernel.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            rows = list(fn())
            for n, us, derived in rows:
                print(f"{n},{us:.1f},{derived}")
            write_bench_json(f"BENCH_{name}.json", rows)
        except Exception:
            failures += 1
            print(f"{name},NaN,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)

    # roofline summary (if the dry-run has been executed)
    if os.path.isdir("experiments/dryrun/pod"):
        from .roofline import format_table, roofline_table
        print("\n# Roofline (single-pod, per chip) — see EXPERIMENTS.md")
        print(format_table(roofline_table()))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
