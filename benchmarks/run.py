"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).  The
roofline table (assignment deliverable g) is emitted at the end when dry-run
artifacts exist under experiments/dryrun/.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from . import (
        bench_accuracy,
        bench_densification,
        bench_hubs,
        bench_interarrival,
        bench_kernel,
        bench_throughput,
    )

    modules = [
        ("densification", bench_densification),
        ("hubs", bench_hubs),
        ("interarrival", bench_interarrival),
        ("accuracy", bench_accuracy),
        ("throughput", bench_throughput),
        ("kernel", bench_kernel),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{name},NaN,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)

    # roofline summary (if the dry-run has been executed)
    if os.path.isdir("experiments/dryrun/pod"):
        from .roofline import format_table, roofline_table
        print("\n# Roofline (single-pod, per chip) — see EXPERIMENTS.md")
        print(format_table(roofline_table()))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
