"""Machine-readable bench artifacts: ``BENCH_*.json`` next to the CSV.

Every bench module prints ``name,us_per_call,derived`` CSV rows (scaffold
contract).  This module serializes the same rows as JSON records::

    [{"name": ..., "us_per_call": ..., "derived": ...,
      "meta": {"devices": ..., "tier": ..., "git_sha": ...}}, ...]

so CI can upload them as artifacts and the regression gate
(``benchmarks/gate.py``) can diff runs without parsing CSV out of logs.
``tier`` is recovered from the row name when the row is tier-specific
(``.../executor_dense_...``, ``.../engine_pallas_...``), else null.
"""
from __future__ import annotations

import json
import os
import re
import subprocess

__all__ = ["git_sha", "rows_to_records", "write_bench_json"]

_TIERS = ("numpy", "dense", "tiled", "pallas", "sparse", "auto", "sampled")


def git_sha() -> str:
    """Short sha of HEAD, or "unknown" outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def _tier_of(name: str) -> str | None:
    for tier in _TIERS:
        if re.search(rf"(^|[_/]){tier}([_/]|$)", name):
            return tier
    return None


def rows_to_records(rows, *, devices: int = 0, quick: bool = False) -> list[dict]:
    sha = git_sha()
    return [
        {
            "name": str(name),
            "us_per_call": float(us),
            "derived": str(derived),
            "meta": {"devices": int(devices), "tier": _tier_of(str(name)),
                     "quick": bool(quick), "git_sha": sha},
        }
        for name, us, derived in rows
    ]


def write_bench_json(path: str, rows, *, devices: int = 0,
                     quick: bool = False) -> str:
    """Write rows as a ``BENCH_*.json`` artifact; returns the path.

    ``quick`` records which bench mode produced the rows — quick and full
    mode share row names but not magnitudes (us_per_call is total wall time
    over differently sized streams), so the regression gate keys on it.
    """
    records = rows_to_records(rows, devices=devices, quick=quick)
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
        f.write("\n")
    return path
