"""SSPerf — equiformer-v2 halo-exchange vs gather on the pod mesh.

    PYTHONPATH=src python -m benchmarks.hillclimb_eqv2
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_arch                        # noqa: E402
from repro.distributed.sharding import Sharder            # noqa: E402
from repro.launch.hlo_cost import analyze_hlo             # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.models.gnn.equiformer_v2 import eqv2_loss_halo # noqa: E402

PEAK, HBM_BW, ICI = 197e12, 819e9, 50e9


def terms(c):
    r = analyze_hlo(c.as_text())
    return {
        "t_compute_ms": r["flops"] / PEAK * 1e3,
        "t_memory_ms": r["bytes"] / HBM_BW * 1e3,
        "t_collective_ms": r["collectives"]["total"] / ICI * 1e3,
        "collective_gb": r["collectives"]["total"] / 1e9,
        "temp_gb": c.memory_analysis().temp_size_in_bytes / 1e9,
    }


def main() -> None:
    mesh = make_production_mesh()
    n_dev = mesh.size
    shard = Sharder.for_mesh(mesh)
    arch = get_arch("equiformer-v2")
    import dataclasses
    cfg = dataclasses.replace(arch.full_config(), d_in=602)  # minibatch_lg
    out = {}

    # baseline: the registry gather cell (minibatch_lg — the misfit shape)
    cell = arch.cells(cfg)["minibatch_lg"]
    step = cell.make_step(shard)
    with mesh:
        c = jax.jit(step, in_shardings=cell.in_shardings(shard),
                    donate_argnums=cell.donate).lower(*cell.abstract_inputs()).compile()
    out["gather_baseline"] = terms(c)

    # halo variant, same graph budget
    N, E = 169_984, 169_984
    n_loc = N // n_dev        # 664
    H_per_peer = max(1, (n_loc // 2) // n_dev + 1)
    e_loc = E // n_dev * 2
    nc = cfg.n_coeff
    sd = jax.ShapeDtypeStruct
    batch = {
        "x": sd((N, cfg.d_in), jnp.float32),
        "halo_send_idx": sd((n_dev, n_dev, H_per_peer), jnp.int32),
        "edge_src_ext": sd((n_dev, e_loc), jnp.int32),
        "edge_dst_loc": sd((n_dev, e_loc), jnp.int32),
        "edge_mask": sd((n_dev, e_loc), jnp.bool_),
        "wigner": sd((n_dev, e_loc, nc, nc), jnp.float32),
        "labels_2d": sd((n_dev, n_loc), jnp.int32),
        "label_mask_2d": sd((n_dev, n_loc), jnp.float32),
    }
    from repro.models.gnn.equiformer_v2 import init_eqv2
    params_abs = jax.eval_shape(lambda: init_eqv2(jax.random.PRNGKey(0), cfg))
    axes = tuple(mesh.axis_names)
    with mesh:
        c2 = jax.jit(lambda p, b: eqv2_loss_halo(p, b, cfg, mesh, axes)).lower(
            params_abs, batch).compile()
    out["halo_exchange"] = terms(c2)
    out["halo_budget"] = {"H_per_peer": H_per_peer, "edge_slots": e_loc}

    os.makedirs("experiments/hillclimb", exist_ok=True)
    with open("experiments/hillclimb/eqv2_minibatch.json", "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
