"""Serving-path benchmark: N concurrent tenants through the NDJSON server.

An in-process load generator opens one real TCP connection per tenant and
pushes that tenant's stream in fixed-size batches (each push waits for its
ack — the serving protocol's synchronous client shape), all tenants
concurrently on one event loop.  The server coalesces admitted pushes on
its latency budget and drives the fleet engine off-loop, so the rows price
the full production path: socket framing + admission + coalescing + one
co-batched engine dispatch per cycle.

Rows (us_per_call = total wall time / measured latency in us):

- ``serving/aggregate_edges_per_s_n{N}`` — accepted edges / elapsed wall
  seconds across all tenants (derived field),
- ``serving/p50_push_ms_n{N}`` / ``serving/p99_push_ms_n{N}`` — engine
  dispatch-cycle latency percentiles from the server's own histogram
  (what ``/metrics`` exports).

The run also asserts ``/healthz`` and ``/metrics`` respond with the
documented shapes, so the CI leg that produces ``BENCH_serving.json``
doubles as the serving smoke test.
"""
from __future__ import annotations

import asyncio
import json
import time

from repro.streams.config import EngineConfig
from repro.streams.generators import bipartite_pa_stream
from repro.streams.server import StreamServer
from repro.streams.wire import normalize_records, records_to_json

__all__ = ["run_serving"]


async def _send(w, msg: dict) -> None:
    w.write((json.dumps(msg, separators=(",", ":")) + "\n").encode())
    await w.drain()


async def _recv(r) -> dict:
    line = await r.readline()
    if not line:
        raise ConnectionError("server closed")
    return json.loads(line)


async def _http_get(host: str, port: int, path: str) -> dict:
    r, w = await asyncio.open_connection(host, port)
    w.write(f"GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n".encode())
    data = await r.read()
    w.close()
    head, body = data.split(b"\r\n\r\n", 1)
    assert b"200" in head.split(b"\r\n", 1)[0], head
    return json.loads(body)


async def _drive_tenant(host: str, port: int, token: str, stream,
                        batch: int) -> int:
    """Push one tenant's whole stream, batch by batch, each awaiting its
    ack; backpressure rejects back off and retry (the documented client
    contract)."""
    reader, writer = await asyncio.open_connection(host, port)
    await _send(writer, {"type": "hello", "token": token})
    hello = await _recv(reader)
    assert hello["type"] == "hello_ok", hello
    accepted = 0
    k = 0
    while k < len(stream.tau):
        sl = slice(k, k + batch)
        rb = normalize_records(stream.tau[sl], stream.edge_i[sl],
                               stream.edge_j[sl])
        await _send(writer, {"type": "push", "records": records_to_json(rb)})
        reply = await _recv(reader)
        if reply["type"] == "ack":
            accepted += reply["accepted"]
            k += batch
        elif reply.get("reason") == "backpressure":
            await asyncio.sleep(0.002)
        else:
            raise AssertionError(f"unexpected push reply: {reply}")
    writer.close()
    return accepted


async def _one_pass(streams, *, tier: str, batch: int,
                    check_http: bool) -> tuple[float, dict]:
    n = len(streams)
    server = StreamServer(
        nt_w=100, alpha0=0.95,
        tenants={f"tenant{s}": s for s in range(n)},
        config=EngineConfig(tier=tier), flush_ms=1.0, queue_limit=256)
    await server.start()
    t0 = time.perf_counter()
    totals = await asyncio.gather(*[
        _drive_tenant(server.host, server.port, f"tenant{s}", streams[s],
                      batch)
        for s in range(n)])
    dt = time.perf_counter() - t0
    if check_http:
        health = await _http_get(server.host, server.http_port, "/healthz")
        assert health["status"] == "ok" and health["n_streams"] == n, health
        metrics = await _http_get(server.host, server.http_port, "/metrics")
        agg = metrics["aggregate"]
        assert agg["edges_accepted"] == sum(totals), agg
        assert agg["push_latency_ms"]["count"] > 0, agg
        assert set(metrics["tenants"]) == {str(s) for s in range(n)}, metrics
    snap = server.metrics.snapshot()
    await server.stop(finalize=True, checkpoint=False)
    assert sum(totals) == sum(len(s.tau) for s in streams)
    return dt, snap


def run_serving(*, quick: bool = False, tier: str = "dense",
                n_tenants: int = 4) -> list[tuple]:
    n_edges = 2_000 if quick else 10_000
    batch = 200
    streams = [bipartite_pa_stream(n_edges, temporal="uniform",
                                   n_unique=n_edges // 5, seed=11 + s)
               for s in range(n_tenants)]

    async def both_passes():
        # warm pass compiles every bucket shape; the timed pass reuses the
        # process-global jit cache, so it measures serving, not compilation
        await _one_pass(streams, tier=tier, batch=batch, check_http=True)
        return await _one_pass(streams, tier=tier, batch=batch,
                               check_http=False)

    dt, snap = asyncio.run(both_passes())
    agg = snap["aggregate"]
    lat = agg["push_latency_ms"]
    total_edges = agg["edges_accepted"]
    rows = [
        (f"serving/aggregate_edges_per_s_n{n_tenants}", dt * 1e6,
         f"{total_edges / dt:.0f} ({agg['pushes']} dispatch cycles, "
         f"{agg['windows_closed']} windows, tier={tier})"),
        (f"serving/p50_push_ms_n{n_tenants}", lat["p50"] * 1e3,
         f"{lat['p50']:.2f}ms over {lat['count']} cycles"),
        (f"serving/p99_push_ms_n{n_tenants}", lat["p99"] * 1e3,
         f"{lat['p99']:.2f}ms (max {lat['max']:.2f}ms)"),
    ]
    return rows


def main() -> None:
    import argparse

    from .artifacts import write_bench_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller per-tenant streams (CI smoke check)")
    ap.add_argument("--tier", default="dense")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = run_serving(quick=args.quick, tier=args.tier,
                       n_tenants=args.tenants)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if not args.no_json:
        write_bench_json("BENCH_serving.json", rows, quick=args.quick)


if __name__ == "__main__":
    main()
