"""Serving-path benchmark: N concurrent tenants through the NDJSON server.

An in-process load generator opens one real TCP connection per tenant and
pushes that tenant's stream in fixed-size batches (each push waits for its
ack — the serving protocol's synchronous client shape), all tenants
concurrently on one event loop.  The server coalesces admitted pushes on
its latency budget and drives the fleet engine off-loop, so the rows price
the full production path: socket framing + admission + coalescing + one
co-batched engine dispatch per cycle.

Rows (us_per_call = total wall time / measured latency in us):

- ``serving/aggregate_edges_per_s_n{N}`` — accepted edges / elapsed wall
  seconds across all tenants (derived field),
- ``serving/aggregate_edges_per_s_n{N}_wal`` — the same pass with the
  write-ahead log on (group-commit fsync per coalesce cycle); the run
  asserts WAL-on stays within 2x of the WAL-off wall time — the durability
  contract's performance half (docs/serving.md),
- ``serving/p50_push_ms_n{N}`` / ``serving/p99_push_ms_n{N}`` — engine
  dispatch-cycle latency percentiles from the server's own histogram
  (what ``/metrics`` exports).

The run also asserts ``/healthz`` and ``/metrics`` respond with the
documented shapes, so the CI leg that produces ``BENCH_serving.json``
doubles as the serving smoke test.  ``--chaos`` runs the SIGKILL/recover
smoke instead: kill the real launcher subprocess at the ``pre_ack`` fault
point mid-stream, restart it on the same state dir, and assert the
recovered estimates are bit-identical to a crash-free offline engine.
"""
from __future__ import annotations

import asyncio
import json
import tempfile
import time

from repro.streams.config import EngineConfig
from repro.streams.generators import bipartite_pa_stream
from repro.streams.server import StreamServer
from repro.streams.wire import normalize_records, records_to_json

__all__ = ["run_serving"]


async def _send(w, msg: dict) -> None:
    w.write((json.dumps(msg, separators=(",", ":")) + "\n").encode())
    await w.drain()


async def _recv(r) -> dict:
    line = await r.readline()
    if not line:
        raise ConnectionError("server closed")
    return json.loads(line)


async def _http_get(host: str, port: int, path: str) -> dict:
    r, w = await asyncio.open_connection(host, port)
    w.write(f"GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n".encode())
    data = await r.read()
    w.close()
    head, body = data.split(b"\r\n\r\n", 1)
    assert b"200" in head.split(b"\r\n", 1)[0], head
    return json.loads(body)


async def _drive_tenant(host: str, port: int, token: str, stream,
                        batch: int) -> int:
    """Push one tenant's whole stream, batch by batch, each awaiting its
    ack; backpressure rejects back off and retry (the documented client
    contract)."""
    reader, writer = await asyncio.open_connection(host, port)
    await _send(writer, {"type": "hello", "token": token})
    hello = await _recv(reader)
    assert hello["type"] == "hello_ok", hello
    accepted = 0
    k = 0
    while k < len(stream.tau):
        sl = slice(k, k + batch)
        rb = normalize_records(stream.tau[sl], stream.edge_i[sl],
                               stream.edge_j[sl])
        await _send(writer, {"type": "push", "records": records_to_json(rb)})
        reply = await _recv(reader)
        if reply["type"] == "ack":
            accepted += reply["accepted"]
            k += batch
        elif reply.get("reason") == "backpressure":
            await asyncio.sleep(0.002)
        else:
            raise AssertionError(f"unexpected push reply: {reply}")
    writer.close()
    return accepted


async def _one_pass(streams, *, tier: str, batch: int,
                    check_http: bool, wal_dir: str | None = None
                    ) -> tuple[float, dict]:
    n = len(streams)
    server = StreamServer(
        nt_w=100, alpha0=0.95,
        tenants={f"tenant{s}": s for s in range(n)},
        config=EngineConfig(tier=tier), flush_ms=1.0, queue_limit=256,
        wal_dir=wal_dir)
    await server.start()
    t0 = time.perf_counter()
    totals = await asyncio.gather(*[
        _drive_tenant(server.host, server.port, f"tenant{s}", streams[s],
                      batch)
        for s in range(n)])
    dt = time.perf_counter() - t0
    if check_http:
        health = await _http_get(server.host, server.http_port, "/healthz")
        assert health["status"] == "ok" and health["n_streams"] == n, health
        metrics = await _http_get(server.host, server.http_port, "/metrics")
        agg = metrics["aggregate"]
        assert agg["edges_accepted"] == sum(totals), agg
        assert agg["push_latency_ms"]["count"] > 0, agg
        assert set(metrics["tenants"]) == {str(s) for s in range(n)}, metrics
    snap = server.metrics.snapshot()
    await server.stop(finalize=True, checkpoint=False)
    assert sum(totals) == sum(len(s.tau) for s in streams)
    return dt, snap


def run_serving(*, quick: bool = False, tier: str = "dense",
                n_tenants: int = 4) -> list[tuple]:
    n_edges = 2_000 if quick else 10_000
    batch = 200
    streams = [bipartite_pa_stream(n_edges, temporal="uniform",
                                   n_unique=n_edges // 5, seed=11 + s)
               for s in range(n_tenants)]

    async def all_passes():
        # warm pass compiles every bucket shape; the timed passes reuse the
        # process-global jit cache, so they measure serving, not compilation
        await _one_pass(streams, tier=tier, batch=batch, check_http=True)
        off = await _one_pass(streams, tier=tier, batch=batch,
                              check_http=False)
        with tempfile.TemporaryDirectory(prefix="sgrapp-bench-wal-") as d:
            on = await _one_pass(streams, tier=tier, batch=batch,
                                 check_http=False, wal_dir=d)
        return off, on

    (dt, snap), (dt_wal, snap_wal) = asyncio.run(all_passes())
    agg = snap["aggregate"]
    lat = agg["push_latency_ms"]
    total_edges = agg["edges_accepted"]
    ratio = dt_wal / dt
    # the durability contract's perf half: group-commit fsync keeps the
    # WAL-on path within 2x of WAL-off
    assert ratio < 2.0, (
        f"WAL-on serving pass is {ratio:.2f}x WAL-off (limit 2x): "
        f"{dt_wal:.3f}s vs {dt:.3f}s")
    rows = [
        (f"serving/aggregate_edges_per_s_n{n_tenants}", dt * 1e6,
         f"{total_edges / dt:.0f} ({agg['pushes']} dispatch cycles, "
         f"{agg['windows_closed']} windows, tier={tier})"),
        (f"serving/aggregate_edges_per_s_n{n_tenants}_wal", dt_wal * 1e6,
         f"{snap_wal['aggregate']['edges_accepted'] / dt_wal:.0f} "
         f"(wal group-commit, {ratio:.2f}x of wal-off, tier={tier})"),
        (f"serving/p50_push_ms_n{n_tenants}", lat["p50"] * 1e3,
         f"{lat['p50']:.2f}ms over {lat['count']} cycles"),
        (f"serving/p99_push_ms_n{n_tenants}", lat["p99"] * 1e3,
         f"{lat['p99']:.2f}ms (max {lat['max']:.2f}ms)"),
    ]
    return rows


def run_chaos(*, quick: bool = False, tier: str = "numpy") -> None:
    """SIGKILL/recover smoke (no benchmark rows): plan a kill at the
    ``pre_ack`` fault point, push through the outage with the retrying
    seq client, restart on the same state dir, and assert bit-identity
    against a crash-free offline engine."""
    import numpy as np

    from repro.streams.engine import StreamingSGrapp
    from repro.streams.faults import DurableClient, FaultPlan, ServerProcess

    nt_w, alpha0 = 30, 0.95
    n_batches = 12 if quick else 24
    stream = bipartite_pa_stream(n_batches * 50, temporal="uniform",
                                 n_unique=n_batches * 12, seed=23)
    batches = [records_to_json(normalize_records(
                   stream.tau[k:k + 50], stream.edge_i[k:k + 50],
                   stream.edge_j[k:k + 50]))
               for k in range(0, len(stream.tau), 50)]
    import socket
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    port = sock.getsockname()[1]
    sock.close()

    async def scenario(ckpt: str) -> dict:
        srv_kw = dict(nt_w=nt_w, alpha0=alpha0, tenants={"t0": 0},
                      checkpoint_dir=ckpt, tier=tier, flush_ms=1.0,
                      extra_args=["--port", str(port), "--http-port", "0"])
        client = DurableClient("127.0.0.1", port, "t0")

        async def push_all():
            return [await client.push(rec) for rec in batches]

        plan = FaultPlan({"pre_ack": {"action": "kill",
                                      "at": n_batches // 2}})
        with ServerProcess(plan=plan, **srv_kw) as srv1:
            srv1.wait_ready()
            await client.connect()
            pusher = asyncio.create_task(push_all())
            code = await asyncio.to_thread(srv1.wait_dead, 120)
            assert code == -9, f"expected SIGKILL exit, got {code}"
            print(f"[chaos] server killed at pre_ack "
                  f"(cycle {n_batches // 2}); restarting...")
            with ServerProcess(plan=None, **srv_kw) as srv2:
                srv2.wait_ready()
                replies = await asyncio.wait_for(pusher, timeout=120)
                assert all(r["type"] == "ack" for r in replies)
                dups = sum(bool(r.get("duplicate")) for r in replies)
                print(f"[chaos] {len(replies)} batches acked through the "
                      f"outage ({dups} deduped retries)")
                final = await client.call({"type": "finalize"})
                client.close()
                return final

    with tempfile.TemporaryDirectory(prefix="sgrapp-chaos-") as d:
        final = asyncio.run(scenario(d))
    eng = StreamingSGrapp(nt_w, alpha0, config=EngineConfig(tier=tier))
    eng.push(stream.tau, stream.edge_i, stream.edge_j)
    ref = eng.finalize()
    np.testing.assert_array_equal(
        np.asarray(final["estimates"], dtype=np.float32), ref.estimates)
    np.testing.assert_array_equal(
        np.asarray(final["counts"], dtype=np.float64), ref.window_counts)
    print(f"[chaos] recovered estimates bit-identical to crash-free run "
          f"({len(ref.estimates)} windows)")


def main() -> None:
    import argparse

    from .artifacts import write_bench_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller per-tenant streams (CI smoke check)")
    ap.add_argument("--tier", default="dense")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--no-json", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="SIGKILL/recover smoke instead of benchmark rows")
    args = ap.parse_args()
    if args.chaos:
        run_chaos(quick=args.quick,
                  tier="numpy" if args.tier == "dense" else args.tier)
        return
    print("name,us_per_call,derived")
    rows = run_serving(quick=args.quick, tier=args.tier,
                       n_tenants=args.tenants)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if not args.no_json:
        write_bench_json("BENCH_serving.json", rows, quick=args.quick)


if __name__ == "__main__":
    main()
