"""Paper Tables 4-6 + Figs 9-12: hub membership in butterflies, degree vs
support correlation, hub-connection-fraction decay, young/old hubs."""
from __future__ import annotations

import time

import numpy as np

from repro.core.analysis import (
    butterfly_hub_fractions,
    degree_support_correlation,
    hub_connection_fraction,
    young_old_hubs,
)

from .common import bench_streams

__all__ = ["run"]


def run() -> list[tuple]:
    rows = []
    for name, s in bench_streams().items():
        n = min(2000, len(s))
        t0 = time.perf_counter()
        fr = butterfly_hub_fractions(s.edge_i[:n], s.edge_j[:n], s.n_i, s.n_j)
        ci, cj = degree_support_correlation(s.edge_i[:n], s.edge_j[:n], s.n_i, s.n_j)
        dt = (time.perf_counter() - t0) * 1e6
        h = fr["hubs_0_4"]
        rows.append((f"hubs/{name}/fractions_0to4", dt,
                     "|".join(f"{x:.2f}" for x in h)))
        rows.append((f"hubs/{name}/deg_support_corr", dt,
                     f"i={ci:.2f} j={cj:.2f}"))
        # Figs 9-10: normalized hub connection fraction decays
        fracs = []
        for k in (500, 1000, 2000):
            deg = np.bincount(s.edge_i[:k], minlength=s.n_i)
            fracs.append(hub_connection_fraction(deg, k))
        rows.append((f"hubs/{name}/conn_fraction_decay", dt,
                     "->".join(f"{x:.4f}" for x in fracs)))
        # Figs 11-12: young vs old hubs at t=2000
        deg = np.bincount(s.edge_i[:n], minlength=s.n_i)
        vts = np.full(s.n_i, np.inf)
        for t in range(n):
            v = s.edge_i[t]
            if vts[v] == np.inf:
                vts[v] = s.tau[t]
        young, old = young_old_hubs(deg, vts, np.unique(s.tau[:n]))
        rows.append((f"hubs/{name}/young_old", dt, f"young={young} old={old}"))
    return rows
