"""Shared benchmark utilities + canonical bench streams."""
from __future__ import annotations

import time

import numpy as np

from repro.core.butterfly import count_butterflies_np
from repro.core.windows import window_bounds
from repro.streams import ba_bipartite_stream, bipartite_pa_stream

__all__ = ["timer_us", "bench_streams", "ground_truth_cumulative"]


def timer_us(fn, *args, repeat: int = 3, **kw) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_streams(n: int = 6000, n_unique: int = 1500):
    """The three canonical streams of the reproduction (SS3.1 methodology):
    hub-dominated uniform (rating-like), hub-dominated bursty (wiki-like),
    and the BA+random-stamps null model."""
    return {
        "pa_uniform": bipartite_pa_stream(n, temporal="uniform",
                                          n_unique=n_unique, seed=0),
        "pa_bursty": bipartite_pa_stream(n, temporal="bursty",
                                         n_unique=n_unique, seed=1),
        "ba_random": ba_bipartite_stream(n=max(n // 8, 64), m=8,
                                         n_unique=n_unique, seed=2),
    }


def ground_truth_cumulative(stream, nt_w: int) -> np.ndarray:
    b = window_bounds(stream.tau, nt_w)
    return np.array(
        [count_butterflies_np(stream.edges()[: int(e)]) for _, e in b],
        dtype=np.float64)
