"""Paper Figs 16-20 + Table 7 + Figs 25-29: MAPE grids over (alpha, N_t^W)
for sGrapp and sGrapp-x (x in 25/50/75/100), the alpha = P(t) hub-probability
exponent, and per-window signed error traces."""
from __future__ import annotations

import time

import numpy as np

from repro.core.analysis import hub_probability_exponent
from repro.core.sgrapp import run_sgrapp, run_sgrapp_x
from repro.core.windows import windowize

from .common import bench_streams, ground_truth_cumulative

__all__ = ["run"]

ALPHAS = [0.80, 0.88, 0.96, 1.04, 1.12, 1.20]
NTWS = [40, 60, 80]


def run() -> list[tuple]:
    rows = []
    for name, s in bench_streams().items():
        best = {"sgrapp": (np.inf, None), "x25": (np.inf, None),
                "x50": (np.inf, None), "x75": (np.inf, None),
                "x100": (np.inf, None)}
        t0 = time.perf_counter()
        for ntw in NTWS:
            wb = windowize(s.tau, s.edge_i, s.edge_j, ntw)
            if wb.n_windows < 4:
                continue
            truths = ground_truth_cumulative(s, ntw)
            for a in ALPHAS:
                m = run_sgrapp(wb, a, truths=truths).mape()
                if m < best["sgrapp"][0]:
                    best["sgrapp"] = (m, (a, ntw))
                for x in (25, 50, 75, 100):
                    mx = run_sgrapp_x(wb, a, truths, x_percent=x).mape()
                    if mx < best[f"x{x}"][0]:
                        best[f"x{x}"] = (mx, (a, ntw))
        dt = (time.perf_counter() - t0) * 1e6
        for variant, (m, arg) in best.items():
            rows.append((f"accuracy/{name}/{variant}_best_mape", dt,
                         f"mape={m:.4f} at(alpha,ntw)={arg}"))
        # error-trace shape at the best sGrapp setting (Fig 25 analogue)
        if best["sgrapp"][1] is not None:
            a, ntw = best["sgrapp"][1]
            wb = windowize(s.tau, s.edge_i, s.edge_j, ntw)
            truths = ground_truth_cumulative(s, ntw)
            errs = run_sgrapp(wb, a, truths=truths).relative_errors()
            rows.append((f"accuracy/{name}/error_trace", dt,
                         f"first={errs[0]:+.3f} mid={errs[len(errs)//2]:+.3f} "
                         f"last={errs[-1]:+.3f}"))
        # Table 7 analogue: alpha = P(t) hub-probability exponent
        p = hub_probability_exponent(s.edge_i, s.edge_j, s.n_i, s.n_j,
                                     min(2000, len(s)))
        rows.append((f"accuracy/{name}/alpha_eq_P(t)", dt, f"P(t=2000)={p:.4f}"))
    return rows
